//! The PJRT execution engine: loads HLO-text artifacts, compiles them on
//! the CPU PJRT client, and exposes typed `run` over host `f32` buffers.
//!
//! One `Engine` per OS thread (the PJRT wrapper types are not `Send`);
//! parameters cross threads as plain `Vec<f32>` — which is exactly the
//! paper's explicit network-transfer arrows between processes.

use super::manifest::{ArtifactInfo, Manifest};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// Host-side tensor handed to / returned from an executable.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data }
    }

    pub fn vec(data: Vec<f32>) -> Self {
        HostTensor { shape: vec![data.len()], data }
    }

    pub fn scalar1(v: f32) -> Self {
        HostTensor { shape: vec![1], data: vec![v] }
    }
}

/// A compiled artifact plus its manifest signature.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub info: ArtifactInfo,
    name: String,
}

impl Executable {
    /// Execute with host tensors; returns the tuple elements as host data.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.info.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, (iname, ishape)) in inputs.iter().zip(&self.info.inputs) {
            if t.shape != *ishape {
                bail!(
                    "{}: input {iname} shape {:?} != manifest {:?}",
                    self.name,
                    t.shape,
                    ishape
                );
            }
            let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
            literals.push(xla::Literal::vec1(&t.data).reshape(&dims)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} result", self.name))?;
        let parts = tuple.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// Per-thread runtime: PJRT client + compiled executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Arc<Manifest>,
    cache: BTreeMap<(String, String), Arc<Executable>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            manifest,
            cache: BTreeMap::new(),
        })
    }

    /// Engine sharing an already-parsed manifest (thread spawns).
    pub fn with_manifest(manifest: Arc<Manifest>) -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            manifest,
            cache: BTreeMap::new(),
        })
    }

    /// Load + compile (cached) an artifact for `task`.
    pub fn load(&mut self, task: &str, artifact: &str) -> Result<Arc<Executable>> {
        let key = (task.to_string(), artifact.to_string());
        if let Some(e) = self.cache.get(&key) {
            return Ok(Arc::clone(e));
        }
        let info = self
            .manifest
            .task(task)?
            .artifacts
            .get(artifact)
            .with_context(|| format!("artifact {task}/{artifact} not in manifest"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            info.file
                .to_str()
                .context("artifact path not valid UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {task}/{artifact}"))?;
        let executable = Arc::new(Executable {
            exe,
            info,
            name: format!("{task}/{artifact}"),
        });
        self.cache.insert(key, Arc::clone(&executable));
        Ok(executable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Engine::new(&root).ok()
    }

    #[test]
    fn actor_infer_runs_and_is_bounded() {
        let Some(mut eng) = engine() else { return };
        let m = Arc::clone(&eng.manifest);
        let t = m.task("ant").unwrap();
        let exe = eng.load("ant", "actor_infer").unwrap();
        let mut rng = crate::util::Rng::new(0);
        let theta = t.layouts["actor"].init(&mut rng);
        let c = m.chunk;
        let mut obs = vec![0.0f32; c * t.obs_dim];
        rng.fill_normal(&mut obs);
        let out = exe
            .run(&[
                HostTensor::vec(theta),
                HostTensor::new(&[c, t.obs_dim], obs),
                HostTensor::vec(vec![0.0; t.obs_dim]),
                HostTensor::vec(vec![1.0; t.obs_dim]),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), c * t.act_dim);
        assert!(out[0].iter().all(|v| v.abs() <= 1.0 && v.is_finite()));
        // tanh of small-init net: not all identical.
        assert!(out[0].iter().any(|v| *v != out[0][0]));
    }

    #[test]
    fn input_shape_mismatch_is_rejected() {
        let Some(mut eng) = engine() else { return };
        let exe = eng.load("ant", "actor_infer").unwrap();
        let bad = vec![HostTensor::vec(vec![0.0; 3])];
        assert!(exe.run(&bad).is_err());
    }

    #[test]
    fn executable_cache_hits() {
        let Some(mut eng) = engine() else { return };
        let a = eng.load("ant", "actor_infer").unwrap();
        let b = eng.load("ant", "actor_infer").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
