//! The device-resident update plane.
//!
//! A steady-state `*_update` call is almost a fixed point: the parameter
//! and optimizer-state outputs (`theta/m/v`, the Polyak target, SAC's
//! temperature triplet) ARE the next call's inputs. The staged host
//! round-trip pays for that twice per step — `Vec<f32>` → literal on the
//! way in, literal → `Vec<f32>` on the way out — for tensors that no host
//! code reads between publishes. This module closes the loop on the
//! staged-literal plane: [`ResidentSpec`] derives the output→input
//! feedback mapping from the manifest signature (outputs and inputs share
//! role names by construction in `aot.py`), and [`ResidentUpdate`] wraps
//! an executable + [`FeedPlan`] so an update loop stages only the
//! per-step batch, fetches only the loss/qmean scalars (and the
//! per-sample `td` vector under prioritized replay), and materializes θ
//! on the host exclusively at bus-publish points via [`to_host`].
//!
//! Bit-identity with the staged path is structural, not numerical luck:
//! the same literals reach the same executable, and `f32 ⇄ Literal`
//! round-trips are exact — `tests/resident.rs` pins this differentially.
//!
//! [`to_host`]: ResidentUpdate::to_host

use super::engine::{Executable, ResidentState, TensorView};
use super::feed::{FeedFrame, FeedPlan};
use super::manifest::ArtifactInfo;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Output→input feedback mapping plus the fetched-output list for one
/// artifact, derived from its manifest signature.
#[derive(Debug, Clone)]
pub struct ResidentSpec {
    /// `(output index, input slot)` pairs: outputs that loop back.
    pub feedback: Vec<(usize, usize)>,
    /// `(output name, output index)` for every output that does NOT loop
    /// back, in manifest order — what `run_resident` returns to the host.
    pub fetch: Vec<(String, usize)>,
}

impl ResidentSpec {
    /// Derive the mapping by role name: an output named like an input
    /// feeds back into that slot (`theta_c → theta_c`, `m → m`, ...);
    /// everything else (losses, diagnostics, `td`) is fetched. A name
    /// match with a shape mismatch is a malformed artifact and fails
    /// loudly rather than silently degrading to a fetch.
    pub fn from_manifest(info: &ArtifactInfo) -> Result<ResidentSpec> {
        let mut feedback = Vec::new();
        let mut fetch = Vec::new();
        for (o, (oname, oshape)) in info.outputs.iter().enumerate() {
            match info.inputs.iter().position(|(iname, _)| iname == oname) {
                Some(slot) => {
                    let ishape = &info.inputs[slot].1;
                    if oshape != ishape {
                        bail!(
                            "resident spec: output {oname} shape {oshape:?} != \
                             input slot {slot} shape {ishape:?}"
                        );
                    }
                    feedback.push((o, slot));
                }
                None => fetch.push((oname.clone(), o)),
            }
        }
        Ok(ResidentSpec { feedback, fetch })
    }

    /// Output indices fetched to the host, in return order.
    pub fn fetch_indices(&self) -> Vec<usize> {
        self.fetch.iter().map(|(_, o)| *o).collect()
    }

    /// Position of a fetched output inside `run_resident`'s return value.
    pub fn fetch_pos(&self, name: &str) -> Option<usize> {
        self.fetch.iter().position(|(n, _)| n == name)
    }

    /// Whether input `slot` is written by feedback (restaging it between
    /// steps would be overwritten by the next run's outputs).
    pub fn is_feedback_slot(&self, slot: usize) -> bool {
        self.feedback.iter().any(|&(_, s)| s == slot)
    }
}

/// One device-resident update stream: executable + plan + resident call
/// state + the Adam step counter (the one feedback-shaped input with no
/// matching output — a single f32 restaged per step, tracked separately
/// from the zero-parameter-bytes invariant).
///
/// # Example
///
/// The V-learner shape: seed once from a fully-bound frame, then per
/// step restage only the minibatch and step (needs a compiled artifact,
/// so not run here):
///
/// ```no_run
/// use pql::runtime::{Engine, FeedDims, FeedPlan, OptState, ResidentUpdate, Variant};
/// use std::sync::Arc;
///
/// # fn main() -> anyhow::Result<()> {
/// let mut eng = Engine::new("rust/artifacts".as_ref())?;
/// let exe = eng.load("ant", "critic_update")?;
/// let t = eng.manifest.task("ant")?.clone();
/// let dims = FeedDims {
///     batch: 512, obs_dim: t.obs_dim, act_dim: t.act_dim,
///     critic_obs_dim: t.critic_obs_dim,
///     actor_params: t.layouts["actor"].size,
///     critic_params: t.layouts["critic"].size,
/// };
/// let critic = OptState::new(vec![0.0; dims.critic_params]);
/// # let (theta_a, s, a, rn, s2, gm, mu, var) =
/// #     (vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1],
/// #      vec![0.0; 1], vec![0.0; 1], vec![0.0; 1], vec![0.0; 1]);
/// let mut res = ResidentUpdate::new(
///     Arc::clone(&exe),
///     FeedPlan::critic_update(Variant::Ddpg, &dims, 5e-4),
///     critic.t,
///     |f| {
///         f.bind_adam(&critic)?;
///         f.bind("target", &critic.theta)?;
///         f.bind("theta_a", &theta_a)?;
///         f.bind("s", &s)?; f.bind("a", &a)?; f.bind("rn", &rn)?;
///         f.bind("s2", &s2)?; f.bind("gmask", &gm)?;
///         f.bind("mu", &mu)?; f.bind("var", &var)?;
///         Ok(())
///     },
/// )?;
/// loop {
///     // θ/m/v/target loop back on device — stage the batch only.
///     res.restage("s", &s)?;
///     res.restage("a", &a)?; res.restage("rn", &rn)?;
///     res.restage("s2", &s2)?; res.restage("gmask", &gm)?;
///     let diagnostics = res.step()?; // fetches loss/qmean scalars only
///     # let _ = diagnostics; break;
/// }
/// let theta_now = res.to_host("theta")?; // materialize at publish points
/// # let _ = theta_now; Ok(())
/// # }
/// ```
pub struct ResidentUpdate {
    exe: Arc<Executable>,
    plan: FeedPlan,
    spec: ResidentSpec,
    state: ResidentState,
    t_slot: usize,
    t: f32,
}

impl ResidentUpdate {
    /// Build from a fully-bound first frame: `bind` must bind every
    /// variable slot exactly as for a staged [`FeedFrame::run`] (including
    /// `bind_adam`, which seeds the step counter from `t0`). The staged
    /// literals become the resident state; after that only batch slots and
    /// bus-published parameters are restaged.
    pub fn new(
        exe: Arc<Executable>,
        plan: FeedPlan,
        t0: f32,
        bind: impl FnOnce(&mut FeedFrame) -> Result<()>,
    ) -> Result<ResidentUpdate> {
        plan.validate(&exe.info)?;
        let spec = ResidentSpec::from_manifest(&exe.info)?;
        if spec.feedback.is_empty() {
            bail!("{} plan has no feedback outputs — not an update artifact", plan.label());
        }
        let t_slot = plan
            .index("t")
            .with_context(|| format!("{} plan has no Adam step slot", plan.label()))?;
        let state = {
            let mut frame = plan.frame();
            bind(&mut frame)?;
            let prepared = frame.with_views(|views| exe.prepare(views))??;
            exe.make_resident(prepared, &spec.feedback, &spec.fetch_indices())?
        };
        Ok(ResidentUpdate { exe, plan, spec, state, t_slot, t: t0 })
    }

    /// Restage one variable slot from host data (batch fields each step;
    /// cross-network parameters and normalizers at their bus cadence).
    /// The manifest shape for the slot is applied, so callers pass flat
    /// slices exactly as they do to [`FeedFrame::bind`].
    pub fn restage(&mut self, name: &str, data: &[f32]) -> Result<()> {
        let slot = self
            .plan
            .index(name)
            .with_context(|| format!("{} plan has no slot {name}", self.plan.label()))?;
        let shape = &self.exe.info.inputs[slot].1;
        self.exe
            .restage_resident(&mut self.state, slot, TensorView::new(shape, data))
    }

    /// One update step: execute, loop the parameter outputs back on
    /// device, advance + restage the Adam step scalar, and return the
    /// fetched outputs in [`ResidentSpec::fetch`] order.
    pub fn step(&mut self) -> Result<Vec<Vec<f32>>> {
        let out = self.exe.run_resident(&mut self.state)?;
        self.t += 1.0;
        let tv = [self.t + 1.0];
        self.exe
            .restage_resident(&mut self.state, self.t_slot, TensorView::new(&[1], &tv))?;
        Ok(out)
    }

    /// Materialize the tensor currently staged in slot `name` on the host
    /// — THE publish-point / eval / checkpoint fetch. For feedback slots
    /// this is the newest update output (moved there by [`step`]).
    ///
    /// [`step`]: ResidentUpdate::step
    pub fn to_host(&self, name: &str) -> Result<Vec<f32>> {
        let slot = self
            .plan
            .index(name)
            .with_context(|| format!("{} plan has no slot {name}", self.plan.label()))?;
        self.state.to_host(slot)
    }

    /// Position of a fetched output (e.g. `"loss"`, `"td"`) in the vector
    /// [`step`] returns — resolve once at loop setup.
    ///
    /// [`step`]: ResidentUpdate::step
    pub fn fetch_pos(&self, name: &str) -> Option<usize> {
        self.spec.fetch_pos(name)
    }

    /// Number of update steps taken (the Adam `t` this stream carries).
    pub fn steps(&self) -> f32 {
        self.t
    }

    pub fn spec(&self) -> &ResidentSpec {
        &self.spec
    }

    pub fn plan(&self) -> &FeedPlan {
        &self.plan
    }

    /// Total f32 elements staged host→device since construction
    /// (initial prepare + every restage, including the per-step `t`).
    pub fn staged_elems(&self) -> u64 {
        self.state.staged_elems()
    }

    /// Total f32 elements fetched device→host by [`step`].
    ///
    /// [`step`]: ResidentUpdate::step
    pub fn fetched_elems(&self) -> u64 {
        self.state.fetched_elems()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn info(inputs: &[(&str, &[usize])], outputs: &[(&str, &[usize])]) -> ArtifactInfo {
        let io = |xs: &[(&str, &[usize])]| {
            xs.iter().map(|(n, s)| (n.to_string(), s.to_vec())).collect()
        };
        ArtifactInfo {
            file: PathBuf::new(),
            inputs: io(inputs),
            outputs: io(outputs),
            sha256: None,
        }
    }

    /// The DDPG critic signature from `aot.py`: θ/m/v/target loop back,
    /// loss/qmean (and PER's td) are fetched, `t` has no feedback source.
    #[test]
    fn critic_update_mapping() {
        let p = 60usize;
        let b = 8usize;
        let art = info(
            &[
                ("theta_c", &[p]), ("m", &[p]), ("v", &[p]), ("t", &[1]),
                ("theta_ct", &[p]), ("theta_a", &[40]), ("s", &[b, 5]),
                ("a", &[b, 3]), ("rn", &[b]), ("s2", &[b, 5]), ("gmask", &[b]),
                ("mu", &[5]), ("var", &[5]), ("lr", &[1]),
            ],
            &[
                ("theta_c", &[p]), ("m", &[p]), ("v", &[p]), ("theta_ct", &[p]),
                ("loss", &[1]), ("qmean", &[1]),
            ],
        );
        let spec = ResidentSpec::from_manifest(&art).unwrap();
        assert_eq!(spec.feedback, vec![(0, 0), (1, 1), (2, 2), (3, 4)]);
        assert_eq!(spec.fetch_indices(), vec![4, 5]);
        assert_eq!(spec.fetch_pos("loss"), Some(0));
        assert_eq!(spec.fetch_pos("qmean"), Some(1));
        assert_eq!(spec.fetch_pos("theta_c"), None);
        assert!(spec.is_feedback_slot(4) && !spec.is_feedback_slot(3));

        // PER variant: isw in, td out — td is fetched, not fed back.
        let art = info(
            &[
                ("theta_c", &[p]), ("m", &[p]), ("v", &[p]), ("t", &[1]),
                ("theta_ct", &[p]), ("theta_a", &[40]), ("s", &[b, 5]),
                ("a", &[b, 3]), ("rn", &[b]), ("s2", &[b, 5]), ("gmask", &[b]),
                ("isw", &[b]), ("mu", &[5]), ("var", &[5]), ("lr", &[1]),
            ],
            &[
                ("theta_c", &[p]), ("m", &[p]), ("v", &[p]), ("theta_ct", &[p]),
                ("loss", &[1]), ("qmean", &[1]), ("td", &[b]),
            ],
        );
        let spec = ResidentSpec::from_manifest(&art).unwrap();
        assert_eq!(spec.feedback, vec![(0, 0), (1, 1), (2, 2), (3, 4)]);
        assert_eq!(spec.fetch_pos("td"), Some(2));
    }

    /// SAC actor: the temperature Adam triplet loops back alongside θ/m/v.
    #[test]
    fn sac_actor_update_mapping() {
        let p = 40usize;
        let b = 8usize;
        let art = info(
            &[
                ("theta_a", &[p]), ("m", &[p]), ("v", &[p]), ("t", &[1]),
                ("theta_c", &[60]), ("log_alpha", &[1]), ("am", &[1]), ("av", &[1]),
                ("s", &[b, 5]), ("noise", &[b, 3]), ("mu", &[5]), ("var", &[5]),
                ("lr", &[1]),
            ],
            &[
                ("theta_a", &[p]), ("m", &[p]), ("v", &[p]),
                ("log_alpha", &[1]), ("am", &[1]), ("av", &[1]),
                ("pi_loss", &[1]), ("alpha_loss", &[1]), ("entropy", &[1]),
            ],
        );
        let spec = ResidentSpec::from_manifest(&art).unwrap();
        assert_eq!(
            spec.feedback,
            vec![(0, 0), (1, 1), (2, 2), (3, 5), (4, 6), (5, 7)]
        );
        assert_eq!(spec.fetch_indices(), vec![6, 7, 8]);
        assert_eq!(spec.fetch_pos("entropy"), Some(2));
    }

    /// PPO: θ/m/v loop back; the three diagnostics are fetched.
    #[test]
    fn ppo_update_mapping() {
        let p = 50usize;
        let art = info(
            &[
                ("theta", &[p]), ("m", &[p]), ("v", &[p]), ("t", &[1]),
                ("s", &[8, 5]), ("critic_s", &[8, 5]), ("a", &[8, 3]),
                ("adv", &[8]), ("ret", &[8]), ("logp_old", &[8]),
                ("mu", &[5]), ("var", &[5]), ("lr", &[1]),
            ],
            &[
                ("theta", &[p]), ("m", &[p]), ("v", &[p]),
                ("pi_loss", &[1]), ("v_loss", &[1]), ("kl", &[1]),
            ],
        );
        let spec = ResidentSpec::from_manifest(&art).unwrap();
        assert_eq!(spec.feedback, vec![(0, 0), (1, 1), (2, 2)]);
        assert_eq!(
            spec.fetch.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["pi_loss", "v_loss", "kl"]
        );
    }

    /// A name match with a shape mismatch is a malformed artifact.
    #[test]
    fn shape_mismatched_name_match_is_rejected() {
        let art = info(
            &[("theta", &[10]), ("t", &[1]), ("lr", &[1])],
            &[("theta", &[11]), ("loss", &[1])],
        );
        assert!(ResidentSpec::from_manifest(&art).is_err());
    }

    /// Inference-style artifacts (no feedback) produce an all-fetch spec;
    /// `ResidentUpdate::new` is where they get rejected.
    #[test]
    fn infer_artifact_has_no_feedback() {
        let art = info(
            &[("theta", &[10]), ("obs", &[4, 5]), ("mu", &[5]), ("var", &[5])],
            &[("actions", &[4, 3])],
        );
        let spec = ResidentSpec::from_manifest(&art).unwrap();
        assert!(spec.feedback.is_empty());
        assert_eq!(spec.fetch_pos("actions"), Some(0));
    }
}
