//! Device runtime + executable-cache integration coverage.
//!
//! Artifact-dependent cases skip (early `return`) when `artifacts/` is
//! absent, like the engine unit tests — the pure key/fallback cases run
//! everywhere.

use pql::runtime::{
    artifact_file_hash, CacheKey, DeviceSpec, Engine, Manifest, Runtime, TensorView,
};
use std::path::Path;
use std::sync::Arc;

fn artifact_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// `auto` with no GPU client must land on CPU, and land there every time
/// (the fallback is a deterministic resolution, not a race).
#[test]
fn auto_falls_back_to_cpu_deterministically() {
    let a = Runtime::isolated(DeviceSpec::Auto).unwrap();
    let b = Runtime::isolated(DeviceSpec::Auto).unwrap();
    assert_eq!(a.device_key(), b.device_key());
    // Default (CPU-only) builds have no GPU client at all, so the landing
    // spot is exactly `cpu`; a `--features gpu` build may legitimately
    // resolve onto a real GPU here.
    #[cfg(not(feature = "gpu"))]
    assert_eq!(a.device_key(), "cpu");
}

/// Explicit `gpu` on a CPU-only build is a hard error (silent CPU
/// training on an explicit GPU request would be worse), while `auto`
/// next to it succeeds.
#[cfg(not(feature = "gpu"))]
#[test]
fn explicit_gpu_without_client_errors() {
    assert!(Runtime::isolated(DeviceSpec::Gpu { ordinal: 0 }).is_err());
    assert!(Runtime::isolated(DeviceSpec::Auto).is_ok());
}

// (Hash-moves-with-content invalidation — the property that stale
// executables can't be served after `make artifacts` regenerates a file
// in place — is pinned by the `file_hash_tracks_content` and
// `cache_key_prefers_manifest_hash_and_separates_devices` unit tests in
// `runtime::exec_cache`; `distinct_artifacts_distinct_entries` below
// covers the key construction against a real manifest.)

/// N threads racing to load the same artifact on one shared runtime:
/// exactly one compile happens (asserted via the cache test hook), every
/// thread gets a working executable, and all hand-outs alias the same
/// compiled object.
#[test]
fn same_artifact_compiles_once_across_threads() {
    let Ok(manifest) = Manifest::load(&artifact_root()) else { return };
    let manifest = Arc::new(manifest);
    let rt = Runtime::isolated(DeviceSpec::Cpu).unwrap();
    const THREADS: usize = 4;

    let mut ptrs: Vec<usize> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..THREADS {
            let rt = Arc::clone(&rt);
            let manifest = Arc::clone(&manifest);
            handles.push(scope.spawn(move || {
                let mut eng = Engine::with_runtime(rt, manifest);
                let exe = eng.load("ant", "actor_infer").unwrap();
                // Execute on this thread to exercise concurrent use of
                // the shared executable, not just concurrent loading.
                let obs_dim = exe.info.inputs[1].1[1];
                let chunk = exe.info.inputs[1].1[0];
                let theta = vec![0.0f32; exe.info.inputs[0].1[0]];
                let obs = vec![0.1f32; chunk * obs_dim];
                let mu = vec![0.0f32; obs_dim];
                let var = vec![1.0f32; obs_dim];
                let out = exe
                    .run_ref(&[
                        TensorView::vec(&theta),
                        TensorView::new(&[chunk, obs_dim], &obs),
                        TensorView::vec(&mu),
                        TensorView::vec(&var),
                    ])
                    .unwrap();
                assert!(out[0].iter().all(|v| v.is_finite()));
                Arc::as_ptr(&exe) as usize
            }));
        }
        for h in handles {
            ptrs.push(h.join().unwrap());
        }
    });

    assert_eq!(rt.cache().compiles(), 1, "one compile across {THREADS} threads");
    assert_eq!(rt.cache().hits(), (THREADS - 1) as u64);
    assert_eq!(rt.cache().len(), 1);
    assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "all threads share one executable");
}

/// A cache-served executable must be indistinguishable from a freshly
/// compiled one: bit-identical `run_ref` outputs on the same inputs.
#[test]
fn cached_and_fresh_executables_match_bitwise() {
    let Ok(manifest) = Manifest::load(&artifact_root()) else { return };
    let manifest = Arc::new(manifest);
    let t = manifest.task("ant").unwrap().clone();
    let chunk = manifest.chunk;

    let mut rng = pql::util::Rng::new(11);
    let theta = t.layouts["actor"].init(&mut rng);
    let mut obs = vec![0.0f32; chunk * t.obs_dim];
    rng.fill_normal(&mut obs);
    let mu = vec![0.1f32; t.obs_dim];
    let var = vec![1.5f32; t.obs_dim];
    let obs_shape = [chunk, t.obs_dim];
    let views = [
        TensorView::vec(&theta),
        TensorView::new(&obs_shape, &obs),
        TensorView::vec(&mu),
        TensorView::vec(&var),
    ];

    // Runtime A: compile once, then fetch the same entry through a second
    // engine (a cache hit) — must be the same object and the same bits.
    let rt_a = Runtime::isolated(DeviceSpec::Cpu).unwrap();
    let mut e1 = Engine::with_runtime(Arc::clone(&rt_a), Arc::clone(&manifest));
    let fresh = e1.load("ant", "actor_infer").unwrap();
    let out_fresh = fresh.run_ref(&views).unwrap();
    let mut e2 = Engine::with_runtime(Arc::clone(&rt_a), Arc::clone(&manifest));
    let cached = e2.load("ant", "actor_infer").unwrap();
    assert!(Arc::ptr_eq(&fresh, &cached));
    assert_eq!(rt_a.cache().compiles(), 1);
    assert_eq!(out_fresh, cached.run_ref(&views).unwrap());

    // Runtime B: an entirely fresh compile of the same file must also
    // produce bit-identical outputs (the cache changes nothing numeric).
    let rt_b = Runtime::isolated(DeviceSpec::Cpu).unwrap();
    let mut e3 = Engine::with_runtime(rt_b, Arc::clone(&manifest));
    let recompiled = e3.load("ant", "actor_infer").unwrap();
    assert!(!Arc::ptr_eq(&fresh, &recompiled));
    assert_eq!(out_fresh, recompiled.run_ref(&views).unwrap());
}

/// Distinct artifacts are distinct cache entries; reloading either is a
/// hit, and manifest-recorded hashes key without re-reading files.
#[test]
fn distinct_artifacts_distinct_entries() {
    let Ok(manifest) = Manifest::load(&artifact_root()) else { return };
    let manifest = Arc::new(manifest);
    let rt = Runtime::isolated(DeviceSpec::Cpu).unwrap();
    let mut eng = Engine::with_runtime(Arc::clone(&rt), Arc::clone(&manifest));
    eng.load("ant", "actor_infer").unwrap();
    eng.load("ant", "actor_update").unwrap();
    assert_eq!(rt.cache().compiles(), 2);
    assert_eq!(rt.cache().len(), 2);
    // Second engine re-fetches both: two hits, still two compiles.
    let mut eng2 = Engine::with_runtime(Arc::clone(&rt), Arc::clone(&manifest));
    eng2.load("ant", "actor_infer").unwrap();
    eng2.load("ant", "actor_update").unwrap();
    assert_eq!(rt.cache().compiles(), 2);
    assert_eq!(rt.cache().hits(), 2);

    // Key construction agrees with whichever hash source the manifest
    // provides for a real artifact.
    let info = &manifest.task("ant").unwrap().artifacts["actor_infer"];
    let key = CacheKey::for_artifact("cpu", info).unwrap();
    match &info.sha256 {
        Some(h) => assert_eq!(key.file_hash, format!("sha256:{h}")),
        None => assert_eq!(key.file_hash, artifact_file_hash(&info.file).unwrap()),
    }
}

/// Compile timings are recorded per compile (the bench plane reads these
/// into `BENCH_learner_feed.json`).
#[test]
fn compile_timings_are_recorded() {
    let Ok(manifest) = Manifest::load(&artifact_root()) else { return };
    let manifest = Arc::new(manifest);
    let rt = Runtime::isolated(DeviceSpec::Cpu).unwrap();
    let mut eng = Engine::with_runtime(Arc::clone(&rt), manifest);
    let exe = eng.load("ant", "actor_infer").unwrap();
    assert!(exe.parse_ms >= 0.0 && exe.compile_ms > 0.0);
    let tms = rt.cache().timings();
    assert_eq!(tms.len(), 1);
    assert_eq!(tms[0].name, "ant/actor_infer");
    assert_eq!(tms[0].device, "cpu");
    assert_eq!(tms[0].compile_ms, exe.compile_ms);
}
