//! `dclaw` — the multi-object reorientation task of §4.5 (Chen et al.
//! 2022a): a 9-joint DClaw hand must reorient *hundreds of different
//! objects* with a single policy. Each environment draws its object from a
//! 256-entry catalog of physical parameters (inertia, friction, contact
//! gain); control runs at 12 Hz (5 sim substeps per policy step → high
//! `sim_cost`), and the headline metric is the success *rate*.

use super::{StepOut, VecEnv};
use crate::envs::dynamics::{clamp, Quat, Servo};
use crate::util::Rng;

pub const OBS_DIM: usize = 26;
pub const ACT_DIM: usize = 9;
const NJ: usize = ACT_DIM;
const DT: f32 = 0.0166;
const SUBSTEPS: usize = 5; // 12 Hz control over ~60 Hz sim
const EP_LEN: u32 = 80; // 12 Hz * ~6.6 s
const SUCCESS_ANGLE: f32 = 0.25;
const CATALOG: usize = 256;

#[derive(Clone, Copy)]
struct ObjectParams {
    inv_inertia: f32,
    damping: f32,
    contact_gain: f32,
}

pub struct DClaw {
    n: usize,
    quat: Vec<Quat>,
    target: Vec<Quat>,
    angvel: Vec<[f32; 3]>,
    jpos: Vec<f32>,
    jvel: Vec<f32>,
    contact: [[f32; NJ]; 3],
    object: Vec<usize>, // catalog index per env
    catalog: Vec<ObjectParams>,
    steps: Vec<u32>,
    // Success-rate bookkeeping (rolling over finished episodes).
    episodes: u64,
    successes: u64,
    succeeded_this_ep: Vec<bool>,
    rng: Rng,
}

impl DClaw {
    pub fn new(n: usize, mut rng: Rng) -> Self {
        let mut geo = Rng::new(0xD0C1A3);
        let mut contact = [[0.0f32; NJ]; 3];
        for row in contact.iter_mut() {
            for v in row.iter_mut() {
                *v = geo.uniform_in(-1.0, 1.0);
            }
        }
        let mut cat = Rng::new(0x0B1EC7);
        let catalog = (0..CATALOG)
            .map(|_| ObjectParams {
                inv_inertia: cat.uniform_in(1.5, 6.0),
                damping: cat.uniform_in(1.0, 4.0),
                contact_gain: cat.uniform_in(0.15, 0.45),
            })
            .collect();
        let mut env = DClaw {
            n,
            quat: vec![Quat::IDENTITY; n],
            target: vec![Quat::IDENTITY; n],
            angvel: vec![[0.0; 3]; n],
            jpos: vec![0.0; n * NJ],
            jvel: vec![0.0; n * NJ],
            contact,
            object: vec![0; n],
            catalog,
            steps: vec![0; n],
            episodes: 0,
            successes: 0,
            succeeded_this_ep: vec![false; n],
            rng: rng.split(),
        };
        for i in 0..n {
            env.reset_env(i);
        }
        env
    }

    fn reset_env(&mut self, i: usize) {
        self.quat[i] = Quat::IDENTITY;
        self.angvel[i] = [0.0; 3];
        for j in 0..NJ {
            self.jpos[i * NJ + j] = 0.0;
            self.jvel[i * NJ + j] = 0.0;
        }
        self.object[i] = self.rng.below(CATALOG);
        // DClaw targets are rotations about near-vertical axes.
        let axis = [
            self.rng.uniform_in(-0.3, 0.3),
            self.rng.uniform_in(-0.3, 0.3),
            1.0,
        ];
        let angle = self.rng.uniform_in(0.6, 2.6);
        self.target[i] = Quat::from_axis_angle(axis, angle);
        self.steps[i] = 0;
        self.succeeded_this_ep[i] = false;
    }

    fn rot_dist(&self, i: usize) -> f32 {
        self.quat[i].angle_to(self.target[i])
    }

    fn write_obs(&self, i: usize, obs: &mut [f32]) {
        let o = &mut obs[i * OBS_DIM..(i + 1) * OBS_DIM];
        let q = self.quat[i];
        let t = self.target[i];
        let p = self.catalog[self.object[i]];
        o[0] = q.w;
        o[1] = q.x;
        o[2] = q.y;
        o[3] = q.z;
        o[4] = t.w;
        o[5] = t.x;
        o[6] = t.y;
        o[7] = t.z;
        o[8] = self.angvel[i][0] * 0.2;
        o[9] = self.angvel[i][1] * 0.2;
        o[10] = self.angvel[i][2] * 0.2;
        for j in 0..NJ {
            o[11 + j] = self.jpos[i * NJ + j];
        }
        o[20] = self.rot_dist(i) / std::f32::consts::PI;
        o[21] = (self.steps[i] as f32 / EP_LEN as f32) * 2.0 - 1.0;
        // Object identity is *partially* observable through its physics.
        o[22] = p.inv_inertia / 6.0;
        o[23] = p.damping / 4.0;
        o[24] = p.contact_gain / 0.45;
        o[25] = 1.0;
    }
}

impl VecEnv for DClaw {
    fn num_envs(&self) -> usize {
        self.n
    }
    fn obs_dim(&self) -> usize {
        OBS_DIM
    }
    fn act_dim(&self) -> usize {
        ACT_DIM
    }
    fn max_episode_len(&self) -> u32 {
        EP_LEN
    }
    fn sim_cost(&self) -> f32 {
        5.0 // 12 Hz control: many substeps per policy step
    }

    fn success_rate(&self) -> Option<f32> {
        if self.episodes == 0 {
            Some(0.0)
        } else {
            Some(self.successes as f32 / self.episodes as f32)
        }
    }

    fn reset_all(&mut self, obs: &mut [f32]) {
        for i in 0..self.n {
            self.reset_env(i);
            self.write_obs(i, obs);
        }
    }

    fn step(&mut self, actions: &[f32], out: &mut StepOut) {
        for i in 0..self.n {
            let a = &actions[i * ACT_DIM..(i + 1) * ACT_DIM];
            let p = self.catalog[self.object[i]];
            let prev_dist = self.rot_dist(i);
            let servo = Servo {
                kp: 30.0,
                kd: 2.0,
                torque_limit: 8.0,
                stiction: 0.4,
                inv_inertia: 2.5,
            };
            for _ in 0..SUBSTEPS {
                for j in 0..NJ {
                    let idx = i * NJ + j;
                    let (mut pj, mut vj) = (self.jpos[idx], self.jvel[idx]);
                    servo.step(&mut pj, &mut vj, clamp(a[j], -1.0, 1.0), DT);
                    self.jpos[idx] = clamp(pj, -1.0, 1.0);
                    self.jvel[idx] = vj;
                }
                let mut torque = [0.0f32; 3];
                for (ax, row) in torque.iter_mut().zip(&self.contact) {
                    for j in 0..NJ {
                        *ax += row[j] * self.jvel[i * NJ + j] * p.contact_gain;
                    }
                }
                for ax in 0..3 {
                    self.angvel[i][ax] += (torque[ax] * p.inv_inertia
                        - p.damping * self.angvel[i][ax])
                        * DT;
                }
                self.quat[i] = self.quat[i].integrate(self.angvel[i], DT);
            }
            self.steps[i] += 1;

            let dist = self.rot_dist(i);
            let energy: f32 = a.iter().map(|x| x * x).sum::<f32>() * 0.005;
            let mut reward = 8.0 * (prev_dist - dist) - 0.2 * dist - energy;
            if dist < SUCCESS_ANGLE && !self.succeeded_this_ep[i] {
                reward += 20.0;
                self.succeeded_this_ep[i] = true;
            }

            let timeout = self.steps[i] >= EP_LEN;
            out.reward[i] = reward;
            out.done[i] = timeout as u32 as f32;
            if timeout {
                self.episodes += 1;
                if self.succeeded_this_ep[i] {
                    self.successes += 1;
                }
                self.reset_env(i);
            }
            self.write_obs(i, &mut out.obs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn objects_differ_across_envs() {
        let env = DClaw::new(64, Rng::new(14));
        let distinct: std::collections::HashSet<_> = env.object.iter().collect();
        assert!(distinct.len() > 10, "only {} distinct objects", distinct.len());
    }

    #[test]
    fn success_rate_counts_episodes() {
        let mut env = DClaw::new(2, Rng::new(15));
        let mut obs = vec![0.0; 2 * OBS_DIM];
        env.reset_all(&mut obs);
        assert_eq!(env.success_rate(), Some(0.0));
        let mut out = StepOut::new(2, OBS_DIM);
        for _ in 0..EP_LEN {
            env.step(&[0.0; 2 * ACT_DIM], &mut out);
        }
        // Two episodes finished, zero successes under null policy.
        assert_eq!(env.episodes, 2);
        assert_eq!(env.success_rate(), Some(0.0));
    }

    #[test]
    fn reaching_target_counts_as_success() {
        let mut env = DClaw::new(1, Rng::new(16));
        let mut obs = vec![0.0; OBS_DIM];
        env.reset_all(&mut obs);
        env.target[0] = env.quat[0];
        let mut out = StepOut::new(1, OBS_DIM);
        for _ in 0..EP_LEN {
            env.step(&[0.0; ACT_DIM], &mut out);
        }
        assert_eq!(env.successes, 1);
        assert_eq!(env.success_rate(), Some(1.0));
    }

    #[test]
    fn object_params_affect_dynamics() {
        // Same actions, two different objects -> different trajectories.
        let mut e = DClaw::new(2, Rng::new(17));
        e.object[0] = 0;
        e.object[1] = 99;
        let mut out = StepOut::new(2, OBS_DIM);
        let acts = vec![0.8f32; 2 * ACT_DIM];
        for _ in 0..20 {
            e.step(&acts, &mut out);
        }
        assert_ne!(e.quat[0], e.quat[1]);
    }
}
