//! Constant-evaluation pass: fold scalar constant subexpressions.
//!
//! The builders leave derived coefficients — `1 − τ`, the Adam
//! `1 − β` terms, `1/B` — as symbolic constant expressions. This pass
//! rewrites the graph, replacing every scalar op whose operands are all
//! constants with a single folded constant node, and re-runs CSE over
//! the whole module (rebuilding through [`Graph::add`] deduplicates any
//! nodes the fold made structurally identical).
//!
//! Folding happens in **f64** and is cast to f32 only at emission. This
//! is load-bearing for bit-parity with the AOT artifacts: JAX folded
//! these same coefficients in python floats, and e.g. `1.0 − 0.9`
//! differs in the last mantissa bit between f32 and f64-then-cast
//! arithmetic.

use super::op::{Graph, OpKind, Payload};

/// f64 value of `id` in `g` if it is a constant node.
fn const_val(g: &Graph, id: usize) -> Option<f64> {
    let n = &g.nodes[id];
    match (n.kind, &n.payload) {
        (OpKind::Constant, Payload::Const(bits)) => Some(f64::from_bits(*bits)),
        _ => None,
    }
}

/// Evaluate a foldable op over constant operands, or `None` if the op
/// kind has no fold rule.
fn eval(kind: OpKind, vals: &[f64]) -> Option<f64> {
    Some(match (kind, vals) {
        (OpKind::Add, [a, b]) => a + b,
        (OpKind::Subtract, [a, b]) => a - b,
        (OpKind::Multiply, [a, b]) => a * b,
        (OpKind::Divide, [a, b]) => a / b,
        (OpKind::Minimum, [a, b]) => a.min(*b),
        (OpKind::Maximum, [a, b]) => a.max(*b),
        (OpKind::Power, [a, b]) => a.powf(*b),
        (OpKind::Sqrt, [a]) => a.sqrt(),
        (OpKind::Rsqrt, [a]) => a.sqrt().recip(),
        (OpKind::Abs, [a]) => a.abs(),
        (OpKind::Tanh, [a]) => a.tanh(),
        _ => return None,
    })
}

/// Fold `g` into a new graph. Scalar-shaped ops whose operands all
/// resolve to constants become constant nodes; everything else is
/// re-added with remapped operands (which re-runs CSE globally). Node
/// IDs are reassigned; parameters and the root tuple are preserved.
pub fn fold(g: &Graph) -> Graph {
    let mut out = Graph::new(g.name.clone());
    let mut map: Vec<usize> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let new_id = match (node.kind, &node.payload) {
            (OpKind::Parameter, Payload::Param(i)) => out.parameter(*i, node.shape.clone()),
            (OpKind::Constant, Payload::Const(bits)) => out.constant(f64::from_bits(*bits)),
            _ => {
                let operands: Vec<usize> = node.operands.iter().map(|&o| map[o]).collect();
                let folded = if node.shape.is_empty() {
                    let vals: Option<Vec<f64>> =
                        operands.iter().map(|&o| const_val(&out, o)).collect();
                    vals.and_then(|vs| eval(node.kind, &vs))
                } else {
                    None
                };
                match folded {
                    Some(v) => out.constant(v),
                    None => out.add(node.kind, node.shape.clone(), operands, node.payload.clone()),
                }
            }
        };
        map.push(new_id);
    }
    out.root = g.root.map(|r| map[r]);
    out
}

#[cfg(test)]
mod tests {
    use super::super::lower::lower;
    use super::super::op::Graph;
    use super::*;

    /// Lowered text of a one-output graph: `broadcast(coeff) * param`.
    fn scaled_param(coeff: impl FnOnce(&mut Graph) -> usize) -> String {
        let mut g = Graph::new("t");
        let p = g.parameter(0, vec![4]);
        let c = coeff(&mut g);
        let cb = g.broadcast_scalar(c, vec![4]);
        let y = g.mul(p, cb);
        g.tuple(vec![y]);
        lower(&fold(&g))
    }

    #[test]
    fn folds_scalar_const_expressions_to_one_constant() {
        let mut g = Graph::new("t");
        let a = g.constant(2.0);
        let b = g.constant(3.0);
        let s = g.add_(a, b);
        let p = g.parameter(0, vec![]);
        let y = g.add_(s, p);
        g.tuple(vec![y]);
        let f = fold(&g);
        let text = lower(&f);
        assert!(text.contains("constant(5)"), "folded 2+3: {text}");
        // The folded graph no longer references the original literals.
        assert!(!text.contains("constant(2)"), "{text}");
        assert!(!text.contains("constant(3)"), "{text}");
    }

    #[test]
    fn folds_in_f64_matching_the_python_compile_layer() {
        // np.float32(1.0 - 0.9) == 0.1f32; folding in f32 would give
        // 0.10000002. The fold must land on the f64-then-cast value.
        let text = scaled_param(|g| {
            let one = g.constant(1.0);
            let b1 = g.constant(0.9);
            g.sub(one, b1)
        });
        assert!(text.contains("constant(0.1)"), "{text}");
        assert!(!text.contains("0.10000002"), "{text}");
    }

    #[test]
    fn folded_symbolic_graph_lowers_identically_to_eager_constants() {
        let sym = scaled_param(|g| {
            let one = g.constant(1.0);
            let tau = g.constant(0.05);
            g.sub(one, tau)
        });
        let eager = scaled_param(|g| g.constant(1.0 - 0.05));
        assert_eq!(sym, eager);
    }

    #[test]
    fn runtime_dependent_scalars_are_left_alone() {
        let mut g = Graph::new("t");
        let t = g.parameter(0, vec![]);
        let b1 = g.constant(0.9);
        let p = g.pow(b1, t); // runtime exponent: not foldable
        let one = g.constant(1.0);
        let bc = g.sub(one, p);
        g.tuple(vec![bc]);
        let text = lower(&fold(&g));
        assert!(text.contains("power("), "{text}");
        assert!(text.contains("subtract("), "{text}");
    }

    #[test]
    fn fold_re_runs_cse_across_the_module() {
        let mut g = Graph::new("t");
        let p = g.parameter(0, vec![2]);
        // Two coefficient spellings that fold to the same value.
        let a = {
            let one = g.constant(1.0);
            let h = g.constant(0.5);
            g.sub(one, h)
        };
        let b = {
            let q = g.constant(0.25);
            let two = g.constant(2.0);
            g.mul(q, two)
        };
        let ab = g.broadcast_scalar(a, vec![2]);
        let bb = g.broadcast_scalar(b, vec![2]);
        let x = g.mul(p, ab);
        let y = g.mul(p, bb);
        let s = g.add_(x, y);
        g.tuple(vec![s]);
        let f = fold(&g);
        // After folding, both branches are broadcast(constant(0.5)) and
        // CSE collapses them: the add's operands coincide.
        let root = f.root.unwrap();
        let add = &f.nodes[f.nodes[root].operands[0]];
        assert_eq!(add.operands[0], add.operands[1]);
    }
}
