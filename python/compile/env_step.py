"""Batched XLA mirrors of the closed-form env dynamics (accelerator-resident
simulation plane).

Each supported task gets two graphs, lowered by `aot.py` at a fixed set of
env counts N (static XLA shapes):

  env_step_n{N}:   (state, action)                    -> (state, obs, reward, done[, cobs])
  step_infer_n{N}: (state, theta_a, mu, var, noise)   -> (state, obs, reward, done, act[, cobs])

The `state` output is named like the `state` input on purpose: the rust
resident plane (`ResidentSpec::from_manifest`) derives the output->input
feedback map by role name, so env state loops back on device and only the
transition fields (obs/reward/done[/act/cobs]) are fetched per step.

Auto-reset stays HOST-side: the rust `DeviceVecEnv` fetches the looped-back
state on done steps, redraws the reset rows from the same xoshiro stream the
host envs use (draws happen only for done envs, in env-index order — the
property that makes host/device trajectories comparable), and restages.
Mirroring the integer RNG inside an all-f32 graph would break that draw
order, so the graphs are reset-free by design.

Parity contract with `rust/src/envs/{ant,ballbalance}.rs`:

- Op ORDER mirrors the rust scalar code exactly (left-associated sums,
  semi-implicit Euler update order, clamp placement). Bit-for-bit parity is
  still unattainable: the XLA CPU backend contracts mul+add chains into FMA
  (measured 1-2 ulp per step, independent of --xla_cpu_enable_fast_math),
  and ant additionally goes through sin/cos where libm and XLA differ in
  the last ulp. So parity is tolerance-banded everywhere — tight for
  ballbalance (pure add/mul/div/sqrt/clamp, ~1e-5 over 200 steps), looser
  for ant (~2e-4) — while done and the steps counter must match exactly
  (see rust/tests/env_parity.rs and python/tests/test_env_step.py).
- Scalar constants that rust computes at runtime in f32 (e.g. the render's
  `r_px = 0.12 * half`) are precomputed here with numpy float32 arithmetic,
  never in python float64.

State row layouts (must match `rust/src/envs/device.rs`):

  ant:         [px, py, vx, vy, th, om, pa0, pa1, pa2, pa3, steps]   (11)
  ballbalance: [bx, by, vx, vy, tx, ty, steps]                       (7)

`steps` rides as f32 (exact integer arithmetic well past any episode len).
"""

import jax.numpy as jnp
import numpy as np

from . import model

F32 = jnp.float32

# Tasks with a device-stepping mirror; everything else stays host-only
# (quaternion/Servo tasks in dynamics.rs are stateful in ways worth their
# own PR — see ROADMAP).
ENV_TASKS = ("ant", "ballbalance_vision")

# Env counts the artifacts are emitted at (static XLA shapes). The large
# sizes exist for the bench sweep and are ant-only to keep the artifact
# set small; parity tests run at 64.
EMIT_NS_QUICK = (64, 256)
EMIT_NS_FULL_ANT = (64, 256, 4096, 16384)

ANT_STATE_DIM = 11
BALL_STATE_DIM = 7

PI = np.float32(np.pi)
TWO_PI = np.float32(2.0) * PI


def state_dim(task):
    return {"ant": ANT_STATE_DIM, "ballbalance_vision": BALL_STATE_DIM}[task]


def emit_ns(task, quick):
    if quick:
        return EMIT_NS_QUICK
    return EMIT_NS_FULL_ANT if task == "ant" else EMIT_NS_QUICK


def wrap_angle(a):
    """Wrap to (-pi, pi] — mirrors the fixed rust `wrap_angle` (the x <= 0
    fixup maps both exact-boundary cases, pi + 2*pi*k and -pi + 2*pi*k,
    onto +pi)."""
    x = jnp.fmod(a + PI, TWO_PI)
    x = jnp.where(x <= 0.0, x + TWO_PI, x)
    return x - PI


# ---------------------------------------------------------------------------
# ant — planar thruster locomotion (rust/src/envs/ant.rs)
# ---------------------------------------------------------------------------

ANT_DT = 0.05
ANT_EP_LEN = 300.0
ANT_TRACK_HALF_WIDTH = 3.0
ANT_MOUNT = tuple(np.float32(m) for m in (0.785, 2.356, -2.356, -0.785))
ANT_TORQUE_ARM = (0.4, -0.4, 0.4, -0.4)


def ant_obs(state):
    """Observation from a state batch [N, 11] -> [N, 12] (write_obs)."""
    vx, vy, th, om = state[:, 2], state[:, 3], state[:, 4], state[:, 5]
    py, pa, steps = state[:, 1], state[:, 6:10], state[:, 10]
    cols = [vx, vy, jnp.sin(th), jnp.cos(th), om, py / ANT_TRACK_HALF_WIDTH]
    o = jnp.stack(cols, axis=1)
    tail = jnp.stack(
        [steps / ANT_EP_LEN * 2.0 - 1.0, jnp.ones_like(steps)], axis=1
    )
    return jnp.concatenate([o, pa, tail], axis=1)


def ant_step(state, action):
    """(state [N,11], action [N,4]) -> (state', obs', reward, done)."""
    px, py = state[:, 0], state[:, 1]
    vx, vy = state[:, 2], state[:, 3]
    th, om = state[:, 4], state[:, 5]
    steps = state[:, 10]
    thrust = jnp.clip(action, -1.0, 1.0)
    # Left-associated sums mirror the rust `+=` accumulation order.
    d0, d1 = th + ANT_MOUNT[0], th + ANT_MOUNT[1]
    d2, d3 = th + ANT_MOUNT[2], th + ANT_MOUNT[3]
    t0, t1, t2, t3 = (thrust[:, k] for k in range(4))
    fx = t0 * jnp.cos(d0) + t1 * jnp.cos(d1) + t2 * jnp.cos(d2) + t3 * jnp.cos(d3)
    fy = t0 * jnp.sin(d0) + t1 * jnp.sin(d1) + t2 * jnp.sin(d2) + t3 * jnp.sin(d3)
    tq = (
        t0 * ANT_TORQUE_ARM[0] + t1 * ANT_TORQUE_ARM[1]
        + t2 * ANT_TORQUE_ARM[2] + t3 * ANT_TORQUE_ARM[3]
    )
    # Semi-implicit Euler with drag, same update order as the rust step.
    vx2 = vx + (2.0 * fx - 0.8 * vx) * ANT_DT
    vy2 = vy + (2.0 * fy - 0.8 * vy) * ANT_DT
    om2 = om + (4.0 * tq - 1.5 * om) * ANT_DT
    px2 = px + vx2 * ANT_DT
    py2 = py + vy2 * ANT_DT
    th2 = wrap_angle(th + om2 * ANT_DT)
    steps2 = steps + 1.0

    a0, a1, a2, a3 = (action[:, k] for k in range(4))
    ctrl = (a0 * a0 + a1 * a1 + a2 * a2 + a3 * a3) * 0.05  # raw actions
    reward = vx2 + 0.5 - ctrl - 0.1 * jnp.abs(om2)
    off = jnp.abs(py2) > ANT_TRACK_HALF_WIDTH
    reward = jnp.where(off, reward - 5.0, reward)
    done = jnp.logical_or(off, steps2 >= ANT_EP_LEN).astype(F32)

    state2 = jnp.concatenate(
        [
            jnp.stack([px2, py2, vx2, vy2, th2, om2], axis=1),
            action,  # prev_act <- raw action (pre-reset, as in rust)
            steps2[:, None],
        ],
        axis=1,
    )
    return state2, ant_obs(state2), reward, done


# ---------------------------------------------------------------------------
# ballbalance_vision — ball-on-plate + 24x24 render (ballbalance.rs/render.rs)
# ---------------------------------------------------------------------------

BALL_DT = 0.05
BALL_EP_LEN = 250.0
BALL_G = 6.0
IMG = 24

# Pixel-center grids, precomputed with the same f32 arithmetic the rust
# rasterizer performs per pixel (render.rs): x = (px + 0.5 - half) / half.
_HALF = np.float32(IMG / 2)
_AXIS = (np.arange(IMG, dtype=np.float32) + np.float32(0.5) - _HALF) / _HALF
_XS = np.tile(_AXIS, IMG)  # x varies fastest: out[py * IMG + px]
_YS = np.repeat(_AXIS, IMG)
_EDGE = np.sqrt(_XS * _XS + _YS * _YS) > np.float32(0.98)
_R_PX = np.float32(0.12) * _HALF  # radius_frac * half, computed in f32
_R_PX1 = _R_PX + np.float32(1.0)


def ball_render(bx, by, tx, ty):
    """Batched mirror of `render_ball` ([N] coords -> [N, 576] frames)."""
    x, y = jnp.asarray(_XS), jnp.asarray(_YS)
    v = 0.35 + 0.15 * (tx[:, None] * x[None, :] + ty[:, None] * y[None, :])
    v = jnp.where(jnp.asarray(_EDGE)[None, :], 0.05, v)
    dx = (x[None, :] - bx[:, None]) * _HALF
    dy = (y[None, :] - by[:, None]) * _HALF
    d = jnp.sqrt(dx * dx + dy * dy)
    # Outside the disc alpha clamps to 0 and the blend is exact identity,
    # so the rust `if d < r_px + 1.0` branch needs no mask.
    alpha = jnp.clip(_R_PX1 - d, 0.0, 1.0)
    v = v * (1.0 - alpha) + 1.0 * alpha
    return jnp.clip(v, 0.0, 1.0)


def ball_obs(state):
    return ball_render(state[:, 0], state[:, 1], state[:, 4], state[:, 5])


def ball_critic_obs(state):
    """[N, 7] state -> [N, 8] critic rows (fill_critic_obs)."""
    bx, by = state[:, 0], state[:, 1]
    dist = jnp.sqrt(bx * bx + by * by)
    return jnp.concatenate(
        [state[:, 0:6], dist[:, None], jnp.ones_like(dist)[:, None]], axis=1
    )


def ball_step(state, action):
    """(state [N,7], action [N,2]) -> (state', obs', reward, done, cobs')."""
    bx, by = state[:, 0], state[:, 1]
    vx, vy = state[:, 2], state[:, 3]
    tx, ty = state[:, 4], state[:, 5]
    steps = state[:, 6]
    tx2 = jnp.clip(tx + jnp.clip(action[:, 0], -1.0, 1.0) * 0.6 * BALL_DT, -0.4, 0.4)
    ty2 = jnp.clip(ty + jnp.clip(action[:, 1], -1.0, 1.0) * 0.6 * BALL_DT, -0.4, 0.4)
    vx2 = vx + (-BALL_G * tx2 - 0.2 * vx) * BALL_DT
    vy2 = vy + (-BALL_G * ty2 - 0.2 * vy) * BALL_DT
    bx2 = bx + vx2 * BALL_DT
    by2 = by + vy2 * BALL_DT
    steps2 = steps + 1.0

    r2 = bx2 * bx2 + by2 * by2
    dist = jnp.sqrt(r2)
    off = dist > 0.95
    reward = 1.0 - 1.5 * dist - 0.05 * (jnp.abs(vx2) + jnp.abs(vy2))
    reward = jnp.where(off, reward - 10.0, reward)
    done = jnp.logical_or(off, steps2 >= BALL_EP_LEN).astype(F32)

    state2 = jnp.stack([bx2, by2, vx2, vy2, tx2, ty2, steps2], axis=1)
    return state2, ball_obs(state2), reward, done, ball_critic_obs(state2)


# ---------------------------------------------------------------------------
# Graph builders (what aot.py lowers)
# ---------------------------------------------------------------------------


def env_step_fn(task):
    """Pure dynamics graph: (state, action) -> transition fields."""
    if task == "ant":
        return ant_step
    if task == "ballbalance_vision":
        return ball_step
    raise ValueError(f"no device env mirror for task {task!r}")


def obs_fn(task):
    return {"ant": ant_obs, "ballbalance_vision": ball_obs}[task]


def env_outputs(task):
    """Output names of env_step_fn, in return order."""
    base = ["state", "obs", "reward", "done"]
    return base + ["cobs"] if task == "ballbalance_vision" else base


def step_infer_fn(spec, task):
    """Fused actor-forward + env-step graph: one dispatch per rollout step.

    The actor sees the obs of the CURRENT state (recomputed on device from
    the resident state — for ballbalance that re-renders the frame the
    previous dispatch produced, which is cheaper than a second obs feedback
    slot), `noise` arrives pre-scaled by the per-env sigma ladder
    (exploration.rs draws it host-side), and the action is clamped in-graph
    exactly like `Noise::apply`.
    """
    step = env_step_fn(task)
    obs_of = obs_fn(task)

    def fused(state, theta_a, mu, var, noise):
        obs0 = obs_of(state)
        act = spec.actor_fwd(theta_a, model.normalize_obs(obs0, mu, var))
        act = jnp.clip(act + noise, -1.0, 1.0)
        out = step(state, act)
        return out[:4] + (act,) + out[4:]

    return fused


def step_infer_outputs(task):
    base = ["state", "obs", "reward", "done", "act"]
    return base + ["cobs"] if task == "ballbalance_vision" else base
