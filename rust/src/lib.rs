//! # PQL — Parallel Q-Learning
//!
//! Reproduction of "Parallel Q-Learning: Scaling Off-policy Reinforcement
//! Learning under Massively Parallel Simulation" (Li et al., ICML 2023).
//!
//! Three-layer architecture:
//! - **Layer 3 (this crate)**: the rust coordinator — Actor / P-learner /
//!   V-learner processes, replay buffers, speed-ratio control, the
//!   massively-parallel environment substrate, and baselines.
//! - **Layer 2**: JAX actor/critic networks + losses + optimizer steps,
//!   AOT-lowered to HLO text at build time (`python/compile/`).
//! - **Layer 1**: Pallas kernels for the compute hot-spots (fused n-step
//!   double-Q TD targets, C51 categorical projection, fused MLP layers).
//!
//! Python never runs on the training path: the rust binary loads the
//! `artifacts/*.hlo.txt` modules through PJRT (`xla` crate) and drives
//! everything else natively.

pub mod algos;
pub mod cli;
pub mod cmd;
pub mod coordinator;
pub mod config;
pub mod device;
pub mod envs;
pub mod exploration;
pub mod metrics;
pub mod replay;
pub mod runtime;
pub mod serve;
pub mod util;

pub use cli::run_cli;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
