//! Versioned broadcast bus — the paper's network-transfer arrows.
//!
//! P-learner publishes π^p to the Actor and V-learner; V-learner publishes
//! Q^v to the P-learner; the serving front publishes whole policy
//! snapshots to its worker pool. All of those channels are now ONE generic
//! [`Bus<T>`]: a single versioned slot, readers poll [`latest`] and only
//! pay the copy when a newer version exists — both transfers stay
//! concurrent with compute, as in Fig. 1.
//!
//! Cross-device transport is explicit: when publisher and subscriber roles
//! resolve to different runtimes (see `runtime::topology`), the snapshot
//! travels through [`Bus::pull`] as a staged-literal copy into the
//! subscriber's `ResidentState` slots (`ResidentUpdate::restage`) —
//! collectives later. Every channel carries relaxed traffic counters
//! ([`BusCounters`]: publishes, deliveries, stale polls, lagged versions)
//! so staleness is observable per channel instead of inferred.
//!
//! [`latest`]: Bus::latest

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A published snapshot with a monotone version.
struct Slot<T> {
    version: u64,
    data: Arc<T>,
}

/// Per-channel traffic counters. Relaxed atomics: these are monitoring
/// signals, not synchronization — the slot mutex orders the data itself.
#[derive(Debug, Default)]
pub struct BusStats {
    publishes: AtomicU64,
    deliveries: AtomicU64,
    stale_polls: AtomicU64,
    lagged_versions: AtomicU64,
}

/// Plain-value snapshot of one channel's [`BusStats`], for metrics rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BusCounters {
    /// Successful `publish` calls (the initial value is not counted).
    pub publishes: u64,
    /// `latest`/`pull` polls that delivered a new version.
    pub deliveries: u64,
    /// `latest`/`pull` polls that found nothing newer than `since`.
    pub stale_polls: u64,
    /// Versions skipped over across all deliveries: a reader that syncs
    /// v3 → v7 never observed v4..v6, contributing 3. Zero means every
    /// subscriber saw every published version.
    pub lagged_versions: u64,
}

/// Multi-producer (usually single), multi-consumer versioned channel.
///
/// # Example
///
/// Readers keep a version cursor and only pay the copy when something
/// newer exists — the idiom every θ subscriber in the trainer uses:
///
/// ```
/// use pql::coordinator::Bus;
///
/// let bus: Bus<Vec<f32>> = Bus::new(vec![0.0; 4]); // version 1
/// let mut seen = bus.version();
///
/// bus.publish(vec![1.0; 4]);
/// let (v, theta) = bus.latest(seen).expect("newer version exists");
/// assert_eq!(*theta, vec![1.0; 4]);
/// seen = v;
///
/// // Already current: no delivery, no clone — just a stale-poll count.
/// assert!(bus.latest(seen).is_none());
/// assert_eq!(bus.counters().stale_polls, 1);
/// ```
pub struct Bus<T> {
    slot: Arc<Mutex<Slot<T>>>,
    stats: Arc<BusStats>,
}

// Manual impl: `Bus<T>` is a pair of shared handles and clones regardless
// of whether `T` itself is `Clone`.
impl<T> Clone for Bus<T> {
    fn clone(&self) -> Self {
        Bus { slot: Arc::clone(&self.slot), stats: Arc::clone(&self.stats) }
    }
}

impl<T> Bus<T> {
    /// Create with an initial value (version 1).
    pub fn new(initial: T) -> Bus<T> {
        Bus {
            slot: Arc::new(Mutex::new(Slot { version: 1, data: Arc::new(initial) })),
            stats: Arc::new(BusStats::default()),
        }
    }

    /// Publish a new value; returns the new version.
    pub fn publish(&self, data: T) -> u64 {
        let mut s = self.slot.lock().unwrap();
        s.version += 1;
        s.data = Arc::new(data);
        self.stats.publishes.fetch_add(1, Ordering::Relaxed);
        s.version
    }

    /// Fetch the newest value if its version exceeds `since`.
    pub fn latest(&self, since: u64) -> Option<(u64, Arc<T>)> {
        let s = self.slot.lock().unwrap();
        if s.version > since {
            self.stats.deliveries.fetch_add(1, Ordering::Relaxed);
            // A reader syncing v_since → v never saw the versions between.
            self.stats
                .lagged_versions
                .fetch_add(s.version - since - 1, Ordering::Relaxed);
            Some((s.version, Arc::clone(&s.data)))
        } else {
            self.stats.stale_polls.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Unconditional snapshot (not counted as a delivery: used for
    /// initial seeding and diagnostics, not the sync loop).
    pub fn snapshot(&self) -> (u64, Arc<T>) {
        let s = self.slot.lock().unwrap();
        (s.version, Arc::clone(&s.data))
    }

    pub fn version(&self) -> u64 {
        self.slot.lock().unwrap().version
    }

    /// The explicit cross-runtime transport step. When a version newer
    /// than `since` exists, `stage` receives the snapshot — for a
    /// subscriber on a different runtime that closure is a
    /// `ResidentUpdate::restage` staged-literal copy into its resident
    /// slots; same-runtime subscribers use the identical path (the copy
    /// is the publish contract either way, so delivery is bit-identical
    /// across runtimes). Returns the delivered version, or `None` when
    /// the subscriber is already current.
    pub fn pull(
        &self,
        since: u64,
        stage: impl FnOnce(&T) -> anyhow::Result<()>,
    ) -> anyhow::Result<Option<u64>> {
        match self.latest(since) {
            Some((v, d)) => {
                stage(&d)?;
                Ok(Some(v))
            }
            None => Ok(None),
        }
    }

    /// Current traffic counters for this channel.
    pub fn counters(&self) -> BusCounters {
        BusCounters {
            publishes: self.stats.publishes.load(Ordering::Relaxed),
            deliveries: self.stats.deliveries.load(Ordering::Relaxed),
            stale_polls: self.stats.stale_polls.load(Ordering::Relaxed),
            lagged_versions: self.stats.lagged_versions.load(Ordering::Relaxed),
        }
    }
}

/// Flat-`f32` parameter channel — the θ blobs the trainer broadcasts.
/// The one and only ParamBus in the tree; `serve` shares it via its typed
/// sibling `Bus<PolicyParams>`.
pub type ParamBus = Bus<Vec<f32>>;

/// Snapshot of the observation normalizer published by the Actor.
#[derive(Clone)]
pub struct NormBus {
    inner: Bus<Vec<f32>>,
    dim: usize,
}

impl NormBus {
    pub fn new(dim: usize) -> NormBus {
        // mean zeros ++ var ones, concatenated.
        let mut init = vec![0.0; dim];
        init.extend(vec![1.0; dim]);
        NormBus { inner: Bus::new(init), dim }
    }

    pub fn publish(&self, mean: &[f32], var: &[f32]) {
        debug_assert_eq!(mean.len(), self.dim);
        let mut data = Vec::with_capacity(2 * self.dim);
        data.extend_from_slice(mean);
        data.extend_from_slice(var);
        self.inner.publish(data);
    }

    /// Zero-copy snapshot: holds the published `mean ++ var` buffer by
    /// `Arc` and exposes borrowed halves — THE read path (the allocating
    /// `get()` is retired; every consumer borrows).
    pub fn view(&self) -> NormView {
        let (_, data) = self.inner.snapshot();
        NormView { data, dim: self.dim }
    }

    /// Version-gated zero-copy snapshot: `Some` only when a version newer
    /// than `since` exists. The device-resident learners restage their
    /// normalizer slots exactly when this fires, so an unchanged
    /// normalizer costs neither a host copy nor a device transfer.
    pub fn latest_view(&self, since: u64) -> Option<(u64, NormView)> {
        self.inner
            .latest(since)
            .map(|(v, data)| (v, NormView { data, dim: self.dim }))
    }

    /// Traffic counters for the normalizer channel.
    pub fn counters(&self) -> BusCounters {
        self.inner.counters()
    }
}

/// Borrow-friendly normalizer snapshot (see [`NormBus::view`]).
pub struct NormView {
    data: Arc<Vec<f32>>,
    dim: usize,
}

impl NormView {
    pub fn mean(&self) -> &[f32] {
        &self.data[..self.dim]
    }

    pub fn var(&self) -> &[f32] {
        &self.data[self.dim..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn versions_are_monotone_and_latest_filters() {
        let bus = ParamBus::new(vec![1.0]);
        assert_eq!(bus.version(), 1);
        assert!(bus.latest(1).is_none());
        let v2 = bus.publish(vec![2.0]);
        assert_eq!(v2, 2);
        let (v, d) = bus.latest(1).unwrap();
        assert_eq!(v, 2);
        assert_eq!(*d, vec![2.0]);
        assert!(bus.latest(2).is_none());
    }

    #[test]
    fn generic_bus_carries_non_vec_payloads() {
        #[derive(PartialEq, Debug)]
        struct P {
            theta: Vec<f32>,
            tag: u32,
        }
        let bus: Bus<P> = Bus::new(P { theta: vec![0.0], tag: 0 });
        bus.publish(P { theta: vec![1.0, 2.0], tag: 7 });
        let (v, p) = bus.latest(1).unwrap();
        assert_eq!(v, 2);
        assert_eq!(*p, P { theta: vec![1.0, 2.0], tag: 7 });
    }

    #[test]
    fn counters_track_publishes_deliveries_and_lag() {
        let bus = ParamBus::new(vec![0.0]);
        assert_eq!(bus.counters(), BusCounters::default());
        bus.publish(vec![1.0]); // v2
        bus.publish(vec![2.0]); // v3
        bus.publish(vec![3.0]); // v4
        // Reader at v1 syncs straight to v4: skipped v2 and v3.
        let (v, _) = bus.latest(1).unwrap();
        assert_eq!(v, 4);
        assert!(bus.latest(v).is_none());
        let c = bus.counters();
        assert_eq!(c.publishes, 3);
        assert_eq!(c.deliveries, 1);
        assert_eq!(c.stale_polls, 1);
        assert_eq!(c.lagged_versions, 2);
        // snapshot() is not a delivery.
        let _ = bus.snapshot();
        assert_eq!(bus.counters().deliveries, 1);
    }

    #[test]
    fn pull_stages_exactly_on_new_versions() {
        let bus = ParamBus::new(vec![1.0, 2.0]);
        let mut staged: Vec<Vec<f32>> = Vec::new();
        // Already current: the stage closure must not run.
        let r = bus
            .pull(1, |d| {
                staged.push(d.clone());
                Ok(())
            })
            .unwrap();
        assert!(r.is_none());
        assert!(staged.is_empty());
        bus.publish(vec![3.0, 4.0]);
        let v = bus
            .pull(1, |d| {
                staged.push(d.clone());
                Ok(())
            })
            .unwrap()
            .unwrap();
        assert_eq!(v, 2);
        assert_eq!(staged, vec![vec![3.0, 4.0]]);
        let c = bus.counters();
        assert_eq!((c.deliveries, c.stale_polls), (1, 1));
    }

    #[test]
    fn no_torn_reads_under_concurrency() {
        // Writers publish vectors where all elements equal the version tag;
        // readers must never observe a mixed vector.
        let bus = ParamBus::new(vec![0.0; 64]);
        let b2 = bus.clone();
        let w = std::thread::spawn(move || {
            for k in 1..200 {
                b2.publish(vec![k as f32; 64]);
            }
        });
        let mut last = 0u64;
        for _ in 0..2000 {
            if let Some((v, d)) = bus.latest(last) {
                assert!(d.iter().all(|x| *x == d[0]), "torn read at v{v}");
                assert!(v > last);
                last = v;
            }
        }
        w.join().unwrap();
    }

    #[test]
    fn norm_bus_roundtrip() {
        let nb = NormBus::new(3);
        let view = nb.view();
        assert_eq!(view.mean(), &[0.0; 3]);
        assert_eq!(view.var(), &[1.0; 3]);
        nb.publish(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        let view = nb.view();
        assert_eq!(view.mean(), &[1.0, 2.0, 3.0]);
        assert_eq!(view.var(), &[4.0, 5.0, 6.0]);
        assert_eq!(nb.counters().publishes, 1);
    }

    #[test]
    fn norm_view_pins_its_snapshot() {
        let nb = NormBus::new(2);
        nb.publish(&[1.0, 2.0], &[3.0, 4.0]);
        let view = nb.view();
        assert_eq!(view.mean(), &[1.0, 2.0]);
        assert_eq!(view.var(), &[3.0, 4.0]);
        // The view pins its own snapshot: later publishes don't mutate it.
        nb.publish(&[9.0, 9.0], &[9.0, 9.0]);
        assert_eq!(view.mean(), &[1.0, 2.0]);
    }

    #[test]
    fn latest_view_is_version_gated() {
        let nb = NormBus::new(2);
        // Initial state is version 1: visible to a fresh reader only.
        let (v1, view) = nb.latest_view(0).unwrap();
        assert_eq!(view.mean(), &[0.0, 0.0]);
        assert_eq!(view.var(), &[1.0, 1.0]);
        assert!(nb.latest_view(v1).is_none(), "no republish → no restage");
        nb.publish(&[1.0, 2.0], &[3.0, 4.0]);
        let (v2, view) = nb.latest_view(v1).unwrap();
        assert!(v2 > v1);
        assert_eq!(view.mean(), &[1.0, 2.0]);
        assert_eq!(view.var(), &[3.0, 4.0]);
        assert!(nb.latest_view(v2).is_none());
    }
}
