//! PQL leader binary — CLI entrypoint. Subcommands are wired in `pql::cli`.

fn main() {
    if let Err(e) = pql::run_cli(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
